"""Serving-engine SLO benchmark: Poisson rate sweep x cache layout x backend.

Drives the fixed-shape continuous-batching engine (v2) with Poisson-ish
synthetic arrival traces (repro/serving/trace.py) on a smoke-size model.
The sweep has three dimensions:

  * attention backend — the plain-XLA oracle first (the before), then the
    Pallas registry path (compiled on TPU, interpret elsewhere — the after);
  * ``cache_layout`` — ``contiguous`` (one (num_slots, cache_len) KV row
    per slot) vs ``paged`` (shared block pool + per-slot block tables);
  * arrival rate — each (backend, layout) engine serves the SAME request
    trace at several requests-per-second rates, so the row set shows how
    TTFT/ITL percentiles degrade as load approaches saturation.

One engine per (backend, layout) is compiled once — a warmup trace hits
every rung of the prefill bucket ladder plus the decode program, so the
compile count is bounded at ``len(PREFILL_BUCKETS) + 1`` per engine and the
timed runs must not retrace (the row is annotated `RETRACED` if one does;
``jax_compile_events_timed`` > 0 is the same signal machine-side).  Each
(backend, layout, rate) cell emits one row:

    serving[<backend>/<layout>@<rate>rps],<us_per_decode_step>,<tok/s +
    TTFT/latency/ITL p50/p95/p99 + attn dispatch provenance>

A row whose trace does not fully drain FAILS the benchmark (RuntimeError):
``latency_summary`` reports ``submitted``/``unfinished`` precisely so
half-served traces cannot masquerade as clean SLO percentiles.

Since PR 8 the whole run records through ``repro.core.telemetry``: request
lifecycles, queue-depth/slot-occupancy gauges, attention dispatch events,
and — via the ``jax.monitoring`` bridge — an XLA compile-event counter per
row.  The trace is exported next to the artifact as
``BENCH_serving_trace.jsonl`` (feed to ``python -m repro.core.telemetry
summarize``) and a Chrome/Perfetto-loadable ``BENCH_serving_trace.json``.

A machine-readable artifact is written to ``BENCH_serving.json`` (schema
``repro.serving/v4``; v3 had a single rate and a single cache layout and no
drain accounting; v2 lacked the SLO columns; v1 was one CSV row).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import telemetry as tel
from repro.core.portable import on_tpu
from repro.core.telemetry.jaxmon import COMPILE_COUNTER
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import (Request, ServingEngine, latency_summary,
                           synthetic_trace)

ARCH = "granite-3-8b"
NUM_SLOTS = 4
CACHE_LEN = 64
PREFILL_BUCKETS = (8, 16)
BLOCK_SIZE = 8
MAX_NEW = 16
RATES_RPS = (10.0, 50.0, 200.0)
CACHE_LAYOUTS = ("contiguous", "paged")
ARTIFACT = "BENCH_serving.json"
SCHEMA = "repro.serving/v4"


def _prov(log: Dict[str, Dict[str, Any]], kind: str) -> str:
    d = log.get(kind, {})
    bk = d.get("backend", "?")
    if d.get("fallback"):
        return f"{kind}={bk}(fallback)"
    tuning = d.get("tuning", "?")
    return f"{kind}={bk}" + (f"/{tuning}" if bk != "xla" else "")


def _compile_count() -> float:
    return tel.snapshot().get("counters", {}).get(COMPILE_COUNTER, 0.0)


def _ms(lat: Dict[str, float], key: str) -> Optional[float]:
    v = lat.get(key)
    return v * 1e3 if v is not None else None


def _warmup_trace(cfg) -> List[Request]:
    """One request per ladder bucket (exact-fit prompts), so every prefill
    shape AND the decode shape compile before anything is timed."""
    rng = np.random.default_rng(7)
    return [
        Request(uid=10_000 + i,
                prompt=rng.integers(2, cfg.vocab_size, b).astype(np.int32),
                max_new_tokens=4, arrival_time=0.0)
        for i, b in enumerate(sorted(PREFILL_BUCKETS))]


def _build_engine(params, cfg, backend: str, layout: str) -> ServingEngine:
    kwargs: Dict[str, Any] = {}
    if layout == "paged":
        kwargs["block_size"] = BLOCK_SIZE
    return ServingEngine(params, cfg, num_slots=NUM_SLOTS,
                         cache_len=CACHE_LEN,
                         prefill_buckets=PREFILL_BUCKETS,
                         attn_backend=backend, cache_layout=layout, **kwargs)


def _one_rate(eng: ServingEngine, cfg, backend: str, layout: str,
              rate: float, n_requests: int, dispatch_log,
              ) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    """One timed row: the warmed (backend, layout) engine serves the trace
    at `rate` requests/second."""
    compiles_before = _compile_count()
    traces_before = (eng.stats["prefill_traces"], eng.stats["decode_traces"])
    steps_before = eng.stats["decode_steps"]
    toks_before = eng.stats["tokens_generated"]

    trace = synthetic_trace(n_requests, vocab_size=cfg.vocab_size,
                            rate=rate, max_prompt=max(PREFILL_BUCKETS),
                            max_new_tokens=MAX_NEW, seed=1)
    t0 = time.perf_counter()
    done = eng.run(trace)
    wall = time.perf_counter() - t0
    compiles_after = _compile_count()

    steps = eng.stats["decode_steps"] - steps_before
    toks = eng.stats["tokens_generated"] - toks_before
    del done  # the engine mutates the trace's Request objects in place —
    # summarizing the full submitted trace is what makes unfinished visible
    lat = latency_summary(trace)
    if lat["unfinished"] > 0:
        raise RuntimeError(
            f"serving[{backend}/{layout}@{rate:g}rps]: trace did not drain "
            f"({lat['unfinished']}/{lat['submitted']} requests unfinished) "
            f"— SLO percentiles would be meaningless")
    retraced = (eng.stats["prefill_traces"],
                eng.stats["decode_traces"]) != traces_before

    # this row's telemetry: drain the ring so per-row events never evict
    # each other across rows, summarize the spans, count compiles
    rec = tel.recorder()
    row_events = rec.drain() if rec is not None else []
    row_tel = {
        "spans": tel.summarize_events(row_events),
        "jax_compile_events": compiles_after - compiles_before,
        "jax_compile_events_timed": compiles_after - compiles_before,
    }

    def fmt(key):
        v = _ms(lat, key)
        return f"{v:.1f}" if v is not None else "n/a"

    derived = (f"{toks / wall:.1f} tok/s "
               f"ttft p50 {fmt('p50_ttft_s')} "
               f"p95 {fmt('p95_ttft_s')} p99 {fmt('p99_ttft_s')} ms "
               f"itl p50 {fmt('p50_itl_s')} "
               f"p95 {fmt('p95_itl_s')} p99 {fmt('p99_itl_s')} ms "
               f"lat p50 {fmt('p50_latency_s')} "
               f"p95 {fmt('p95_latency_s')} p99 {fmt('p99_latency_s')} ms "
               f"({n_requests} reqs @ {rate:g} rps slots={NUM_SLOTS}) "
               f"compiles={row_tel['jax_compile_events']:.0f} "
               f"{_prov(dispatch_log, 'prefill')} "
               f"{_prov(dispatch_log, 'decode')}"
               + (" RETRACED" if retraced else ""))
    emit(f"serving[{backend}/{layout}@{rate:g}rps]",
         wall / max(steps, 1), derived)
    row = {
        "backend": backend,
        "cache_layout": layout,
        "rate_rps": rate,
        "resolved": dict(eng.attn_backends),
        "tok_s": toks / wall,
        "us_per_decode_step": wall / max(steps, 1) * 1e6,
        "ttft_p50_ms": _ms(lat, "p50_ttft_s"),
        "ttft_p95_ms": _ms(lat, "p95_ttft_s"),
        "ttft_p99_ms": _ms(lat, "p99_ttft_s"),
        "itl_p50_ms": _ms(lat, "p50_itl_s"),
        "itl_p95_ms": _ms(lat, "p95_itl_s"),
        "itl_p99_ms": _ms(lat, "p99_itl_s"),
        "latency_p50_ms": _ms(lat, "p50_latency_s"),
        "latency_p95_ms": _ms(lat, "p95_latency_s"),
        "latency_p99_ms": _ms(lat, "p99_latency_s"),
        "requests": n_requests,
        "submitted": lat["submitted"],
        "unfinished": lat["unfinished"],
        "retraced": retraced,
        "jax_compile_events": row_tel["jax_compile_events"],
        "telemetry": row_tel,
        "dispatch": dispatch_log,
    }
    return row, row_events


def _one_engine(params, cfg, backend: str, layout: str, n_requests: int,
                ) -> Tuple[List[Dict[str, Any]], List[Dict[str, Any]],
                           Dict[str, Any]]:
    """Warm one (backend, layout) engine through the whole bucket ladder,
    then serve the rate sweep on it."""
    A.reset_dispatch_log()
    compiles_before = _compile_count()
    eng = _build_engine(params, cfg, backend, layout)
    eng.run(_warmup_trace(cfg))
    log = A.dispatch_log()
    warm_compiles = _compile_count() - compiles_before
    # the bounded-compile contract: one prefill program per ladder rung,
    # one decode program — never a shape per prompt length
    if eng.stats["prefill_traces"] > len(PREFILL_BUCKETS):
        raise RuntimeError(
            f"serving[{backend}/{layout}]: {eng.stats['prefill_traces']} "
            f"prefill traces for a {len(PREFILL_BUCKETS)}-bucket ladder")
    if eng.stats["decode_traces"] != 1:
        raise RuntimeError(
            f"serving[{backend}/{layout}]: expected exactly one decode "
            f"trace, got {eng.stats['decode_traces']}")
    rec = tel.recorder()
    events = rec.drain() if rec is not None else []   # warmup events

    rows = []
    for rate in RATES_RPS:
        row, row_events = _one_rate(eng, cfg, backend, layout, rate,
                                    n_requests, log)
        row["warmup_jax_compile_events"] = warm_compiles
        rows.append(row)
        events.extend(row_events)
    engine_meta = {
        "backend": backend,
        "cache_layout": layout,
        "prefill_traces": eng.stats["prefill_traces"],
        "decode_traces": eng.stats["decode_traces"],
        "warmup_jax_compile_events": warm_compiles,
    }
    return rows, events, engine_meta


def run(smoke: bool = False, json_path: str = ARTIFACT) -> Dict[str, Any]:
    cfg = get_config(ARCH, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # record the whole run; respect an env-configured recorder
    # (REPRO_TELEMETRY=jsonl:... keeps its exit flush), else enable an
    # in-memory one for the duration of the benchmark
    owned = not tel.enabled()
    if owned:
        tel.configure("on")

    try:
        # before: the status-quo plain-XLA path; after: the registry Pallas
        # kernels (compiled on TPU, interpret mode on a CPU host — relative
        # numbers only there, see benchmarks/common.py)
        backends = ["xla", "pallas" if on_tpu() else "pallas_interpret"]
        n_requests = 5 if smoke else 16

        rows: List[Dict[str, Any]] = []
        engines: List[Dict[str, Any]] = []
        events: List[Dict[str, Any]] = []
        for bk in backends:
            for layout in CACHE_LAYOUTS:
                erows, eevents, emeta = _one_engine(params, cfg, bk, layout,
                                                    n_requests)
                rows.extend(erows)
                events.extend(eevents)
                engines.append(emeta)

        rec = tel.recorder()
        events.extend(rec.drain() if rec is not None else [])
        stem = json_path[:-5] if json_path.endswith(".json") else json_path
        trace_jsonl = f"{stem}_trace.jsonl"
        trace_chrome = f"{stem}_trace.json"
        meta = {"benchmark": "serving", "arch": ARCH, "schema_of": SCHEMA}
        if rec is not None:
            snap = rec.snapshot()     # counters/gauges survive the drains
            snap["span_summary"] = tel.summarize_events(events)
            tel.write_jsonl(trace_jsonl, events, meta=meta,
                            footer_data=snap)
            tel.write_chrome_trace(trace_chrome, events, meta=meta)
        else:  # pragma: no cover - recorder always on here
            snap = {}

        artifact = {
            "schema": SCHEMA,
            "arch": ARCH,
            "smoke": bool(smoke),
            "platform": jax.devices()[0].platform,
            "num_slots": NUM_SLOTS,
            "cache_len": CACHE_LEN,
            "prefill_buckets": list(PREFILL_BUCKETS),
            "block_size": BLOCK_SIZE,
            "cache_layouts": list(CACHE_LAYOUTS),
            "rates_rps": list(RATES_RPS),
            "jax_compile_events": snap.get("counters", {}).get(
                COMPILE_COUNTER, 0.0),
            "telemetry": snap,
            "trace_jsonl": trace_jsonl,
            "trace_chrome": trace_chrome,
            "engines": engines,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        return artifact
    finally:
        if owned:
            tel.configure("off")


if __name__ == "__main__":
    run()
