"""Serving-engine benchmark: throughput + latency, per attention backend.

Drives the fixed-shape continuous-batching engine with a Poisson-ish
synthetic arrival trace (repro/serving/trace.py) on a smoke-size model,
once per attention backend — the plain-XLA oracle first (the before), then
the Pallas registry path (compiled on TPU, interpret elsewhere — the
after).  Each backend emits one row:

    serving[<backend>],<us_per_decode_step>,<tok/s + TTFT + latency + attn
    dispatch provenance>

The dispatch provenance comes from ``models/attention.dispatch_log()``,
captured at trace time while the engine compiles its two programs: which
registry backend each program actually dispatched to and whether its block
sizes came from the tuning cache (``exhaustive``/``coordinate``) or the
declared defaults (``miss-default``).

A small warmup trace triggers the two compiles (one prefill shape, one
decode shape) before timing; the measured run must not retrace — the row is
annotated `RETRACED` if it does, since that invalidates the timing.  A
machine-readable artifact is written to ``BENCH_serving.json`` (schema
``repro.serving/v2``; v1 was the single pre-PR-6 CSV row with no backend
dimension).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.portable import on_tpu
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import ServingEngine, latency_summary, synthetic_trace

ARCH = "granite-3-8b"
NUM_SLOTS = 4
CACHE_LEN = 64
PREFILL_LEN = 16
RATE_RPS = 50.0
MAX_NEW = 16
ARTIFACT = "BENCH_serving.json"
SCHEMA = "repro.serving/v2"


def _prov(log: Dict[str, Dict[str, Any]], kind: str) -> str:
    d = log.get(kind, {})
    bk = d.get("backend", "?")
    if d.get("fallback"):
        return f"{kind}={bk}(fallback)"
    tuning = d.get("tuning", "?")
    return f"{kind}={bk}" + (f"/{tuning}" if bk != "xla" else "")


def _one_backend(params, cfg, backend: str, n_requests: int
                 ) -> Dict[str, Any]:
    A.reset_dispatch_log()
    eng = ServingEngine(params, cfg, num_slots=NUM_SLOTS,
                        cache_len=CACHE_LEN, prefill_len=PREFILL_LEN,
                        attn_backend=backend)

    warm = synthetic_trace(NUM_SLOTS, vocab_size=cfg.vocab_size, rate=1e6,
                           max_prompt=PREFILL_LEN, max_new_tokens=4,
                           seed=7, uid_base=10_000)
    eng.run(warm)
    # both programs are compiled now; the dispatch log holds what each
    # traced — snapshot before the timed run (which must not retrace)
    log = A.dispatch_log()
    traces_before = (eng.stats["prefill_traces"], eng.stats["decode_traces"])
    steps_before = eng.stats["decode_steps"]
    toks_before = eng.stats["tokens_generated"]

    trace = synthetic_trace(n_requests, vocab_size=cfg.vocab_size,
                            rate=RATE_RPS, max_prompt=PREFILL_LEN,
                            max_new_tokens=MAX_NEW, seed=1)
    t0 = time.perf_counter()
    done = eng.run(trace)
    wall = time.perf_counter() - t0

    steps = eng.stats["decode_steps"] - steps_before
    toks = eng.stats["tokens_generated"] - toks_before
    lat = latency_summary(done)
    retraced = (eng.stats["prefill_traces"],
                eng.stats["decode_traces"]) != traces_before
    derived = (f"{toks / wall:.1f} tok/s "
               f"ttft p50 {lat['p50_ttft_s'] * 1e3:.1f} ms "
               f"p95 {lat['p95_ttft_s'] * 1e3:.1f} ms "
               f"lat p50 {lat['p50_latency_s'] * 1e3:.1f} ms "
               f"p95 {lat['p95_latency_s'] * 1e3:.1f} ms "
               f"({n_requests} reqs @ {RATE_RPS:.0f} rps "
               f"slots={NUM_SLOTS}) "
               f"{_prov(log, 'prefill')} {_prov(log, 'decode')}"
               + (" RETRACED" if retraced else ""))
    emit(f"serving[{backend}]", wall / max(steps, 1), derived)
    return {
        "backend": backend,
        "resolved": dict(eng.attn_backends),
        "tok_s": toks / wall,
        "us_per_decode_step": wall / max(steps, 1) * 1e6,
        "ttft_p50_ms": lat["p50_ttft_s"] * 1e3,
        "ttft_p95_ms": lat["p95_ttft_s"] * 1e3,
        "latency_p50_ms": lat["p50_latency_s"] * 1e3,
        "latency_p95_ms": lat["p95_latency_s"] * 1e3,
        "requests": n_requests,
        "retraced": retraced,
        "dispatch": log,
    }


def run(smoke: bool = False, json_path: str = ARTIFACT) -> Dict[str, Any]:
    cfg = get_config(ARCH, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # before: the status-quo plain-XLA path; after: the registry Pallas
    # kernels (compiled on TPU, interpret mode on a CPU host — relative
    # numbers only there, see benchmarks/common.py)
    backends = ["xla", "pallas" if on_tpu() else "pallas_interpret"]
    n_requests = 8 if smoke else 24

    rows = [_one_backend(params, cfg, bk, n_requests) for bk in backends]

    artifact = {
        "schema": SCHEMA,
        "arch": ARCH,
        "smoke": bool(smoke),
        "platform": jax.devices()[0].platform,
        "num_slots": NUM_SLOTS,
        "cache_len": CACHE_LEN,
        "prefill_len": PREFILL_LEN,
        "rows": rows,
    }
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    return artifact


if __name__ == "__main__":
    run()
