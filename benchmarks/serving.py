"""Serving-engine benchmark: throughput + per-request latency under load.

Drives the fixed-shape continuous-batching engine with a Poisson-ish
synthetic arrival trace (repro/serving/trace.py) on a smoke-size model and
emits one row:

    serving,<us_per_decode_step>,<tok/s + p50/p95 request latency>

A small warmup trace triggers the two compiles (one prefill shape, one
decode shape) before timing; the measured run must not retrace — the row is
annotated `RETRACED` if it does, since that invalidates the timing.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import ServingEngine, latency_summary, synthetic_trace

ARCH = "granite-3-8b"
NUM_SLOTS = 4
CACHE_LEN = 64
PREFILL_LEN = 16
N_REQUESTS = 24
RATE_RPS = 50.0
MAX_NEW = 16


def run() -> None:
    cfg = get_config(ARCH, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, num_slots=NUM_SLOTS,
                        cache_len=CACHE_LEN, prefill_len=PREFILL_LEN)

    warm = synthetic_trace(NUM_SLOTS, vocab_size=cfg.vocab_size, rate=1e6,
                           max_prompt=PREFILL_LEN, max_new_tokens=4,
                           seed=7, uid_base=10_000)
    eng.run(warm)
    traces_before = (eng.stats["prefill_traces"], eng.stats["decode_traces"])
    steps_before = eng.stats["decode_steps"]
    toks_before = eng.stats["tokens_generated"]

    trace = synthetic_trace(N_REQUESTS, vocab_size=cfg.vocab_size,
                            rate=RATE_RPS, max_prompt=PREFILL_LEN,
                            max_new_tokens=MAX_NEW, seed=1)
    t0 = time.perf_counter()
    done = eng.run(trace)
    wall = time.perf_counter() - t0

    steps = eng.stats["decode_steps"] - steps_before
    toks = eng.stats["tokens_generated"] - toks_before
    lat = latency_summary(done)
    retraced = (eng.stats["prefill_traces"],
                eng.stats["decode_traces"]) != traces_before
    derived = (f"{toks / wall:.1f} tok/s "
               f"p50 {lat['p50_latency_s'] * 1e3:.1f} ms "
               f"p95 {lat['p95_latency_s'] * 1e3:.1f} ms "
               f"({N_REQUESTS} reqs @ {RATE_RPS:.0f} rps "
               f"slots={NUM_SLOTS})"
               + (" RETRACED" if retraced else ""))
    emit("serving", wall / max(steps, 1), derived)
