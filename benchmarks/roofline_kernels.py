"""Paper Fig. 2 + Tables 2-3 analogue — static kernel profiles.

The paper uses ncu; our dry-run substitute derives, per science kernel:
arithmetic intensity (FLOP/byte), claimed VMEM working set per BlockSpec,
and the roofline placement against the TPU-v5e peaks.  Derived column:
AI + bound classification.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.hlo_cost import analyze_hlo
from repro.core.roofline import TPU_V5E
from repro.kernels.hartree_fock import ops as hf_ops
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import ops as mb_ops
from repro.kernels.stencil7 import kernel as st_kernel
from repro.kernels.stencil7 import ops as st_ops
from repro.kernels.babelstream import ops as bs_ops


def _profile(name, fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = analyze_hlo(compiled.as_text())
    ai = cost.flops / max(cost.hbm_bytes, 1.0)
    ridge = TPU_V5E.peak_flops / TPU_V5E.hbm_bw     # ~240 FLOP/byte on v5e
    bound = "compute-bound" if ai > ridge else "memory-bound"
    emit(f"roofline.{name}", 0.0,
         f"AI={ai:.3f}FLOP/B {bound}")
    return ai


def run() -> None:
    rng = np.random.default_rng(0)

    u = jax.ShapeDtypeStruct((128, 128, 128), jnp.float32)
    _profile("stencil7.L128", st_ops.laplacian_xla, u)
    emit("roofline.stencil7.vmem_set", 0.0,
         f"{st_kernel.vmem_working_set_bytes((128,128,128), 4, 64)}B")

    n = 1 << 22
    a = jax.ShapeDtypeStruct((n,), jnp.float32)
    _profile("babelstream.triad", lambda b, c: bs_ops.ref.triad(b, c), a, a)
    _profile("babelstream.dot", lambda x, y: bs_ops.ref.dot(x, y), a, a)

    deck = mb_ops.make_deck(natpro=256, natlig=16, nposes=2048, seed=0)
    deck_sds = tuple(jax.ShapeDtypeStruct(d.shape, d.dtype) for d in deck)
    _profile("minibude.fasten", mb_ops.fasten_xla, *deck_sds)

    pos = jax.ShapeDtypeStruct((16, 3), jnp.float32)
    dens = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    _profile("hartree_fock.a16", hf_ops.fock_xla, pos, dens)


if __name__ == "__main__":
    run()
