"""Paper Fig. 2 + Tables 2-3 analogue — static kernel profiles.

The paper uses ncu; our dry-run substitute walks the live registry instead
of a hand-kept kernel list: the conformance CASES supply every family's
canonical shape, so a kernel registered tomorrow is profiled today.  Per
kernel it derives arithmetic intensity twice — from the compiled
(post-fusion) HLO via ``hlo_cost.analyze_hlo`` and from the PR-9 jaxpr
traffic census — and places the cell on the detected chip's roofline.
Derived column: compiled AI, census AI cross-check, and the bound verdict.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core import conformance
from repro.core.analysis import cost
from repro.core.analysis import jaxpr_utils as JU
from repro.core.hlo_cost import analyze_hlo, arithmetic_intensity
from repro.core.portable import registry
from repro.core.roofline import detect_chip
from repro.kernels.stencil7 import kernel as st_kernel

#: profiled backend — the compiled-HLO lane needs a single-device compile,
#: and every family registers an ``xla`` cell
BACKEND = "xla"


def _profile(kernel: str) -> None:
    case = conformance.CASES.get(kernel)
    if case is None:        # registry family without a conformance case yet
        emit(f"roofline.{kernel}", 0.0, "no conformance case")
        return
    args, kwargs = case()
    fn = registry.get(kernel).backends[BACKEND].fn

    compiled = jax.jit(fn).lower(*args, **kwargs).compile()
    hlo = analyze_hlo(compiled.as_text())
    ai_hlo = arithmetic_intensity(hlo)

    # cross-check: the execution-free jaxpr census the static auditor uses
    traffic = cost.census(JU.trace(fn, args, kwargs))
    ai_jaxpr = traffic.arithmetic_intensity

    chip = detect_chip()
    v = cost.verdict(traffic, chip)
    emit(f"roofline.{kernel}", 0.0,
         f"AI={ai_hlo:.3f}FLOP/B (census {ai_jaxpr:.3f}) "
         f"{v.bound}-bound@{chip.name} ridge={chip.ridge:.0f}")


def run() -> None:
    kernels = sorted({k for k, b in conformance.conformance_pairs()
                      if b == BACKEND})
    for kernel in kernels:
        _profile(kernel)
    emit("roofline.stencil7.vmem_set", 0.0,
         f"{st_kernel.vmem_working_set_bytes((128, 128, 128), 4, 64)}B")


if __name__ == "__main__":
    run()
