"""Paper Figs. 6-7 / Eq. 3 — miniBUDE fasten GFLOP/s.

The paper sweeps PPWI x workgroup size; the TPU analogues are poses-per-
grid-step (lane tile) and the deck size.  bm1-proportioned deck, CPU-scaled.
"""

from __future__ import annotations

from benchmarks.common import emit, time_call
from repro.core.metrics import minibude_ops
from repro.kernels.minibude import ops

DECK = dict(natpro=256, natlig=16, nposes=4096)
TILE_SWEEP = [128, 256]   # PPWI analogue


def run() -> None:
    deck = ops.make_deck(**DECK, seed=0)
    for tile in TILE_SWEEP:
        total_ops = minibude_ops(tile, DECK["natlig"], DECK["natpro"],
                                 DECK["nposes"])
        t = time_call(ops.fasten_xla, *deck)
        emit(f"minibude.xla.ppwi{tile}", t,
             f"{total_ops / t / 1e9:.2f}GFLOP/s")
        t = time_call(ops.fasten_pallas, *deck, pose_tile=tile,
                      interpret=True, iters=3, warmup=1)
        emit(f"minibude.pallas_interp.ppwi{tile}", t,
             f"{total_ops / t / 1e9:.2f}GFLOP/s")


if __name__ == "__main__":
    run()
