"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` lines (scaffold contract).
`derived` carries the paper's figure of merit for that table (GB/s, GFLOP/s,
ms, Phi, ...).

CPU-host caveat (recorded once here, applies to all wall-clock numbers): this
container measures XLA:CPU and Pallas-interpret executions — meaningful for
*relative* comparisons and for exercising the Eq. 1-4 machinery, not as TPU
performance.  TPU-projected numbers come from the dry-run roofline
(EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

ROWS: List[Tuple[str, float, str]] = []


def time_call(fn: Callable, *args, iters: int = 10, warmup: int = 2,
              **kwargs) -> float:
    """Median seconds per call, first (JIT) calls discarded (paper §3)."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str) -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
