"""Paper Fig. 3 / Eq. 1 — seven-point stencil effective bandwidth.

Sweeps problem size L, precision, and y-block size (the TPU analogue of the
paper's grid-dim sweep), for the XLA oracle and the Pallas kernel
(interpret mode on CPU).  Derived column: effective GB/s per Eq. 1.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.metrics import stencil7_effective_bytes
from repro.kernels.stencil7 import ops

# CPU-scaled sizes (the paper uses 512/1024 on 94-128 GB GPUs)
SIZES = [(64, 64, 128), (128, 128, 128)]
BLOCK_SWEEP = [16, 32, 64]


def run() -> None:
    rng = np.random.default_rng(0)
    for dtype, tag in ((jnp.float32, "fp32"),):
        for shape in SIZES:
            L = shape[0]
            u = jnp.asarray(rng.standard_normal(shape), dtype)
            eff_bytes = stencil7_effective_bytes(L, u.dtype.itemsize)

            t = time_call(ops.laplacian_xla, u)
            emit(f"stencil7.xla.L{L}.{tag}", t,
                 f"{eff_bytes / t / 1e9:.2f}GB/s")

            for by in BLOCK_SWEEP:
                if shape[1] % by:
                    continue
                t = time_call(ops.laplacian_pallas, u, by=by,
                              interpret=True, iters=3, warmup=1)
                emit(f"stencil7.pallas_interp.L{L}.{tag}.by{by}", t,
                     f"{eff_bytes / t / 1e9:.2f}GB/s")


if __name__ == "__main__":
    run()
